"""Model assembly: block dispatch, scan-over-superblocks, embedding/frontends,
training loss, prefill and decode.

The layer stack is `n_super` repetitions of the config's block pattern (the
"superblock"), executed with `lax.scan` over stacked parameters so HLO size
is O(period), not O(n_layers), and rematerialized per superblock.  Caches and
recurrent states ride the same scan as stacked pytrees, giving uniform
train / prefill / decode entry points for every family (dense, MoE, hybrid
Mamba, xLSTM, VLM/audio stubs).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, moe as moe_mod, ssm, xlstm
from repro.models.layers import Params

FRONTEND_DIM = 1024  # feature dim delivered by the (stubbed) modality encoder


# ---------------------------------------------------------------------------
# single block (mixer + optional FFN/MoE)
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, slot: int) -> Params:
    kind = cfg.pattern[slot]
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {"norm1": jnp.ones((cfg.d_model,), dt)}
    if kind in ("attn", "attn_chunked"):
        p["core"] = layers.init_attention(k1, cfg)
    elif kind == "mamba":
        p["core"] = ssm.init_mamba(k1, cfg)
    elif kind == "mlstm":
        p["core"] = xlstm.init_mlstm(k1, cfg)
    elif kind == "slstm":
        p["core"] = xlstm.init_slstm(k1, cfg)
    else:
        raise ValueError(kind)
    moe_cfg = cfg.moe_for(slot)
    if moe_cfg is not None:
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = moe_mod.init_moe(k2, cfg, moe_cfg)
    elif cfg.d_ff:
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = layers.init_mlp(k3, cfg)
    return p


def _mixer_apply(p, x, cfg, kind, positions, q_offset, state):
    if kind in ("attn", "attn_chunked"):
        return layers.attention_apply(
            p, x, cfg, kind=kind, positions=positions, q_offset=q_offset
        )
    if kind == "mamba":
        return ssm.mamba_apply(p, x, cfg, state)
    if kind == "mlstm":
        return xlstm.mlstm_apply(p, x, cfg, state)
    if kind == "slstm":
        return xlstm.slstm_apply(p, x, cfg, state)
    raise ValueError(kind)


def _mixer_decode(p, x, cache, pos, cfg, kind):
    if kind in ("attn", "attn_chunked"):
        return layers.attention_decode(p, x, cache, pos, cfg, kind=kind)
    if kind == "mamba":
        return ssm.mamba_decode(p, x, cache, cfg)
    if kind == "mlstm":
        return xlstm.mlstm_decode(p, x, cache, cfg)
    if kind == "slstm":
        return xlstm.slstm_decode(p, x, cache, cfg)
    raise ValueError(kind)


def block_apply(p, x, cfg, slot, positions, q_offset=0, state=None):
    kind = cfg.pattern[slot]
    h = layers.rms_norm(x, p["norm1"], cfg.norm_eps)
    mix, cache = _mixer_apply(p["core"], h, cfg, kind, positions, q_offset,
                              state)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        h = layers.rms_norm(x, p["norm2"], cfg.norm_eps)
        moe_cfg = cfg.moe_for(slot)
        if moe_cfg is not None:
            y, aux = moe_mod.moe_apply(p["ffn"], h, cfg, moe_cfg)
        else:
            y = layers.mlp_apply(p["ffn"], h, cfg)
        x = x + y
    return x, cache, aux


def block_decode(p, x, cache, pos, cfg, slot):
    kind = cfg.pattern[slot]
    h = layers.rms_norm(x, p["norm1"], cfg.norm_eps)
    mix, cache = _mixer_decode(p["core"], h, cache, pos, cfg, kind)
    x = x + mix
    if "ffn" in p:
        h = layers.rms_norm(x, p["norm2"], cfg.norm_eps)
        moe_cfg = cfg.moe_for(slot)
        if moe_cfg is not None:
            y, _ = moe_mod.moe_apply(p["ffn"], h, cfg, moe_cfg)
        else:
            y = layers.mlp_apply(p["ffn"], h, cfg)
        x = x + y
    return x, cache


def init_block_cache(cfg: ModelConfig, slot: int, batch: int, s_max: int):
    kind = cfg.pattern[slot]
    if kind in ("attn", "attn_chunked"):
        return layers.init_attn_cache(cfg, batch, s_max, kind)
    if kind == "mamba":
        return ssm.init_mamba_state(cfg, batch)
    if kind == "mlstm":
        return xlstm.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return xlstm.init_slstm_state(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def init_superblock(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, len(cfg.pattern))
    return {f"b{j}": init_block(ks[j], cfg, j) for j in range(len(cfg.pattern))}


def init_model(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4 + cfg.n_super)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "super": jax.vmap(lambda k: init_superblock(k, cfg))(ks[4:]),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["head"] = layers.init_dense(ks[1], cfg.d_model, (cfg.vocab,), dt)
    if cfg.frontend:
        p["frontend_proj"] = layers.init_dense(
            ks[2], FRONTEND_DIM, (cfg.d_model,), dt
        )
    return p


def embed_inputs(p: Params, cfg: ModelConfig, batch: dict[str, Any]):
    """tokens (B, S_tok) [+ features (B, S_f, FRONTEND_DIM)] -> (B, S, d)."""
    dt = cfg.act_dtype
    x = p["embed"].astype(dt)[batch["tokens"]]
    if cfg.frontend:
        feats = jnp.einsum(
            "bsf,fd->bsd", batch["features"].astype(dt),
            p["frontend_proj"].astype(dt),
        )
        x = jnp.concatenate([feats, x], axis=1)
    return x


def forward(
    p: Params,
    cfg: ModelConfig,
    batch: dict[str, Any],
    *,
    collect_cache: bool = False,
    remat_policy: str = "nothing",
    act_spec=None,  # PartitionSpec for the (B, S, d) residual stream
):
    """Full forward (train / prefill).  Returns (logits, aux, caches).

    `act_spec` constrains the scan carry (the only activation saved per
    superblock under remat): without it the (n_super, B, S, d) residuals are
    replicated over "model" — 26 GB/device at 88 layers x 4k (measured)."""
    x = embed_inputs(p, cfg, batch)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)

    def constrain(x):
        if act_spec is not None:
            return jax.lax.with_sharding_constraint(x, act_spec)
        return x

    x = constrain(x)

    # (per-block remat inside the superblock was tried and REFUTED: peak
    # temp got slightly worse — XLA's buffer assignment already bounds the
    # live window per block; see EXPERIMENTS.md §Perf)
    def sb(carry, sbp):
        x, aux = carry
        caches = {}
        for j in range(len(cfg.pattern)):
            x, cache, a = block_apply(sbp[f"b{j}"], x, cfg, j, positions)
            x = constrain(x)
            caches[f"b{j}"] = cache
            aux = aux + a
        return (x, aux), caches if collect_cache else None

    policy = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[remat_policy]
    sb = jax.checkpoint(sb, policy=policy)
    (x, aux), caches = jax.lax.scan(
        sb, (x, jnp.zeros((), jnp.float32)), p["super"]
    )
    x = layers.rms_norm(x, p["final_norm"], cfg.norm_eps)
    head = (p["embed"].T if cfg.tie_embeddings else p["head"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, head.astype(cfg.act_dtype)
    ).astype(jnp.float32)
    return logits, aux, caches


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy, f32.  logits (B, S, V), labels (B, S)."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def train_loss(p: Params, cfg: ModelConfig, batch, remat_policy="nothing",
               act_spec=None):
    """batch: tokens (B, S), labels (B, S_total) — for frontend archs the
    label stream covers the frontend positions too (stub targets)."""
    logits, aux, _ = forward(p, cfg, batch, remat_policy=remat_policy,
                             act_spec=act_spec)
    return softmax_xent(logits, batch["labels"]) + aux


def prefill(p: Params, cfg: ModelConfig, batch, act_spec=None):
    """Returns (last-position logits (B, V), decode-ready caches).  Cache
    leaves are stacked (n_super, B, S, ...); chunked-attention slots are
    rearranged into decode's ring layout."""
    logits, _, caches = forward(p, cfg, batch, collect_cache=True,
                                act_spec=act_spec)

    def fix(path_cache, slot_kind):
        if slot_kind == "attn_chunked":
            return jax.tree.map(
                lambda kv: layers.ring_from_prefill(kv, cfg.chunk_size,
                                                    axis=2),
                path_cache,
            )
        return path_cache

    caches = {
        k: fix(v, cfg.pattern[int(k[1:])]) for k, v in caches.items()
    }
    return logits[:, -1], caches


def grow_attn_caches(caches, cfg: ModelConfig, extra: int):
    """Pad full-attention K/V caches by `extra` positions (decode headroom).
    Chunked/recurrent slots are fixed-size and pass through."""
    out = {}
    for k, v in caches.items():
        if cfg.pattern[int(k[1:])] == "attn":
            out[k] = jax.tree.map(
                lambda kv: jnp.pad(
                    kv, [(0, 0), (0, 0), (0, extra)] + [(0, 0)] * (kv.ndim - 3)
                ),
                v,
            )
        else:
            out[k] = v
    return out


def decode_step(p: Params, cfg: ModelConfig, tokens, caches, pos):
    """One token for every sequence.  tokens (B, 1); caches as from
    prefill/init_decode_caches; pos scalar int32.  Returns (logits, caches)."""
    dt = cfg.act_dtype
    x = p["embed"].astype(dt)[tokens]

    def sb(x, xs):
        sbp, cache = xs
        new = {}
        for j in range(len(cfg.pattern)):
            x, c = block_decode(sbp[f"b{j}"], x, cache[f"b{j}"], pos, cfg, j)
            new[f"b{j}"] = c
        return x, new

    x, new_caches = jax.lax.scan(sb, x, (p["super"], caches))
    x = layers.rms_norm(x, p["final_norm"], cfg.norm_eps)
    head = (p["embed"].T if cfg.tie_embeddings else p["head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dt))[:, 0]
    return logits.astype(jnp.float32), new_caches


def init_decode_caches(cfg: ModelConfig, batch: int, s_max: int):
    """Stacked (n_super, ...) cache pytree for decode-from-scratch (and the
    decode dry-run cells, via eval_shape)."""
    def one(_):
        return {
            f"b{j}": init_block_cache(cfg, j, batch, s_max)
            for j in range(len(cfg.pattern))
        }

    return jax.tree.map(
        lambda *xs: jnp.stack(xs), *[one(i) for i in range(cfg.n_super)]
    ) if cfg.n_super > 1 else jax.tree.map(
        lambda x: x[None], one(0)
    )
