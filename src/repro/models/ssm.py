"""Mamba selective-SSM block (Jamba's sequence mixer).

Training/prefill runs a chunked scan: an outer `lax.scan` over sequence
chunks carrying the (B, d_inner, n_state) recurrent state, an inner scan over
the positions of one chunk.  This bounds live memory to one chunk of
discretized parameters instead of the full (B, S, d_inner, n_state)
materialization (which would be terabytes at Jamba scale), while staying a
single fused HLO loop for the compiler.  Decode reuses the identical
single-position step function, so train/decode equivalence is testable.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, init_dense

CHUNK = 64


def init_mamba(key, cfg: ModelConfig) -> Params:
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))
    return {
        "in_proj": init_dense(ks[0], d, (2 * di,), dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
                   / math.sqrt(cfg.ssm_conv)).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": init_dense(ks[2], di, (r + 2 * n,), dt),
        "dt_proj": init_dense(ks[3], r, (di,), dt),
        "dt_bias": jnp.full((di,), -4.6, dt),  # softplus^-1(0.01)
        "a_log": jnp.log(a),  # f32: continuous-time decay
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": init_dense(ks[4], di, (d,), dt),
    }


def init_mamba_state(cfg: ModelConfig, batch: int):
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner),
                          cfg.act_dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def _ssm_step(h, x_t, dt_t, b_t, c_t, a):
    """One recurrence step.  h (B, di, n) f32; x_t, b_t, c_t bf16;
    dt_t (B, di) f32; a (di, n) negative f32.  Returns (h_new, y_t)."""
    da = jnp.exp(dt_t[..., None] * a[None])  # (B, di, n)
    drive = (dt_t * x_t.astype(jnp.float32))[..., None] \
        * b_t.astype(jnp.float32)[:, None, :]
    h = h * da + drive
    y = jnp.sum(h * c_t.astype(jnp.float32)[:, None, :], axis=-1)
    return h, y


def _pre_scan(p: Params, x: jax.Array, cfg: ModelConfig, conv_tail):
    """Everything before the recurrence: in_proj, causal depthwise conv,
    silu, parameter projections.  conv_tail (B, K-1, di) is the carry-in for
    decode/prefill continuation.  Returns (xs, dts, bs, cs, z, new_tail)."""
    dt = cfg.act_dtype
    di, n, r, kconv = cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    xz = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(dt))
    xs, z = xz[..., :di], xz[..., di:]
    ext = jnp.concatenate([conv_tail, xs], axis=1)  # (B, K-1+S, di)
    new_tail = ext[:, -(kconv - 1):] if kconv > 1 else ext[:, :0]
    conv = sum(
        p["conv_w"][j].astype(dt)
        * jax.lax.dynamic_slice_in_dim(ext, j, xs.shape[1], axis=1)
        for j in range(kconv)
    )
    xs = jax.nn.silu(conv + p["conv_b"].astype(dt))
    dbl = jnp.einsum("bsi,ik->bsk", xs, p["x_proj"].astype(dt))
    dt_r, b, c = dbl[..., :r], dbl[..., r:r + n], dbl[..., r + n:]
    dts = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, p["dt_proj"].astype(dt)).astype(
            jnp.float32
        )
        + p["dt_bias"].astype(jnp.float32)
    )
    # xs/b/c stay bf16 (they only enter elementwise products); dts stays
    # f32 (softplus/exp decay precision).  Halves the mamba pre-scan
    # footprint at jamba scale (4x 268 MB/layer -> 2x, measured).
    return (xs, dts, b, c, z, new_tail)


def mamba_apply(
    p: Params, x: jax.Array, cfg: ModelConfig, state=None
) -> tuple[jax.Array, Params]:
    """Train/prefill: x (B, S, d) -> (y (B, S, d), final state)."""
    bsz, s, _ = x.shape
    if state is None:
        state = init_mamba_state(cfg, bsz)
    xs, dts, bs, cs, z, tail = _pre_scan(p, x, cfg, state["conv"])
    a = -jnp.exp(p["a_log"])

    lc = CHUNK
    while s % lc:
        lc //= 2
    nch = s // lc

    def chunk(h, inputs):
        cx, cdt, cb, cc = inputs  # (lc, B, ...)

        def pos(h, pin):
            x_t, dt_t, b_t, c_t = pin
            h, y = _ssm_step(h, x_t, dt_t, b_t, c_t, a)
            return h, y

        h, ys = jax.lax.scan(pos, h, (cx, cdt, cb, cc))
        return h, ys

    def to_chunks(arr):  # (B, S, ...) -> (nch, lc, B, ...)
        arr = jnp.moveaxis(arr, 1, 0)  # (S, B, ...)
        return arr.reshape((nch, lc) + arr.shape[1:])

    h, ys = jax.lax.scan(
        jax.checkpoint(chunk),
        state["ssm"],
        (to_chunks(xs), to_chunks(dts), to_chunks(bs), to_chunks(cs)),
    )
    ys = jnp.moveaxis(ys.reshape((s,) + ys.shape[2:]), 0, 1)  # (B, S, di)
    y = (ys + p["d_skip"][None, None] * xs.astype(jnp.float32))
    y = y.astype(cfg.act_dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(cfg.act_dtype))
    return out, {"conv": tail.astype(cfg.act_dtype), "ssm": h}


def mamba_decode(
    p: Params, x: jax.Array, state: Params, cfg: ModelConfig
) -> tuple[jax.Array, Params]:
    """x (B, 1, d) -> (y (B, 1, d), new state).  Same math, S=1."""
    xs, dts, bs, cs, z, tail = _pre_scan(p, x, cfg, state["conv"])
    a = -jnp.exp(p["a_log"])
    h, y = _ssm_step(state["ssm"], xs[:, 0], dts[:, 0], bs[:, 0], cs[:, 0], a)
    y = (y + p["d_skip"][None] * xs[:, 0].astype(jnp.float32))
    y = y.astype(cfg.act_dtype)[:, None]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(cfg.act_dtype))
    return out, {"conv": tail.astype(cfg.act_dtype), "ssm": h}
