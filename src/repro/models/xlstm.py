"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, inherently sequential) — [arXiv:2405.04517].

mLSTM trains with the chunkwise formulation: intra-chunk quadratic attention
with log-gate decays + inter-chunk recurrent (C, n, m) state, all stabilized
in log space.  This avoids materializing the (B, H, Dh, Dh) matrix state per
position (the recurrent form would checkpoint terabytes at 4k train).  Decode
uses the exact single-step recurrence; chunked-vs-recurrent equivalence is a
unit test.

sLSTM is sequential by design (the xLSTM paper accepts this); we scan over
positions with per-head block-diagonal recurrence.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, init_dense

MLSTM_CHUNK = 64
NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig) -> Params:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    return {
        "wq": init_dense(ks[0], d, (h, hd), dt),
        "wk": init_dense(ks[1], d, (h, hd), dt),
        "wv": init_dense(ks[2], d, (h, hd), dt),
        "wi": init_dense(ks[3], d, (h,), dt),
        "wf": init_dense(ks[4], d, (h,), dt),
        "bi": jnp.zeros((h,), dt),
        "bf": jnp.full((h,), 3.0, dt),  # open forget gates at init
        "wo_gate": init_dense(ks[5], d, (d,), dt),
        "out": init_dense(ks[6], d, (d,), dt),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int):
    h, hd = cfg.n_heads, cfg.hd
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), NEG, jnp.float32),
    }


def _mlstm_qkv_gates(p: Params, x: jax.Array, cfg: ModelConfig):
    dt = cfg.act_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt)) / math.sqrt(cfg.hd)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    li = (jnp.einsum("bsd,dh->bsh", x, p["wi"].astype(dt))
          + p["bi"].astype(dt)).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(
        (jnp.einsum("bsd,dh->bsh", x, p["wf"].astype(dt))
         + p["bf"].astype(dt)).astype(jnp.float32)
    )
    # head-major f32 for the scan math
    to = lambda t: jnp.moveaxis(t.astype(jnp.float32), 2, 1)  # (B,H,S,hd)
    return to(q), to(k), to(v), li.swapaxes(1, 2), lf.swapaxes(1, 2)


def mlstm_step(q_t, k_t, v_t, li_t, lf_t, state):
    """Exact single-position recurrence (decode + equivalence oracle).
    q_t..v_t (B, H, hd); li_t, lf_t (B, H)."""
    c, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf_t + m, li_t)
    i_p = jnp.exp(li_t - m_new)
    f_p = jnp.exp(lf_t + m - m_new)
    c = f_p[..., None, None] * c + i_p[..., None, None] * (
        v_t[..., :, None] * k_t[..., None, :]
    )  # (B,H,hd_v,hd_k)
    n = f_p[..., None] * n + i_p[..., None] * k_t
    num = jnp.einsum("bhvk,bhk->bhv", c, q_t)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t)), jnp.exp(-m_new)
    )
    h_t = num / den[..., None]
    return h_t, {"C": c, "n": n, "m": m_new}


def _mlstm_chunk(state, q, k, v, li, lf):
    """Chunkwise-parallel form.  q..v (B,H,L,hd); li, lf (B,H,L).
    Returns (h (B,H,L,hd), new state).  Matches repeated mlstm_step."""
    c_in, n_in, m_in = state["C"], state["n"], state["m"]
    b_cum = jnp.cumsum(lf, axis=-1)  # inclusive: b_t
    g_total = b_cum[..., -1]

    # stabilizers
    a_s = li - b_cum  # li_s - b_s
    m_intra = b_cum + jax.lax.cummax(a_s, axis=a_s.ndim - 1)  # max_{s<=t}
    m_inter = m_in[..., None] + b_cum
    m_t = jnp.maximum(m_intra, m_inter)  # (B,H,L)

    # intra-chunk: D_ts = exp(li_s + b_t - b_s - m_t) for s <= t
    dmat = li[..., None, :] + b_cum[..., :, None] - b_cum[..., None, :] \
        - m_t[..., :, None]
    ls = li.shape[-1]
    causal = jnp.tril(jnp.ones((ls, ls), bool))
    dmat = jnp.where(causal, dmat, NEG)
    dexp = jnp.exp(dmat)  # (B,H,L,L)
    qk = jnp.einsum("bhld,bhsd->bhls", q, k)
    h_intra = jnp.einsum("bhls,bhsd->bhld", qk * dexp, v)
    n_intra = jnp.einsum("bhls,bhsd->bhld", dexp, k)

    # inter-chunk contribution
    w_inter = jnp.exp(m_in[..., None] + b_cum - m_t)  # (B,H,L)
    h_inter = jnp.einsum("bhvk,bhlk->bhlv", c_in, q) * w_inter[..., None]
    n_inter = n_in[..., None, :] * w_inter[..., None]

    num = h_intra + h_inter
    n_vec = n_intra + n_inter
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhlk,bhlk->bhl", n_vec, q)), jnp.exp(-m_t)
    )
    h_out = num / den[..., None]

    # state update to the chunk end
    m_out = jnp.maximum(
        g_total + m_in, jnp.max(li + g_total[..., None] - b_cum, axis=-1)
    )
    w_c = jnp.exp(li + g_total[..., None] - b_cum - m_out[..., None])
    c_out = jnp.exp(g_total + m_in - m_out)[..., None, None] * c_in \
        + jnp.einsum("bhl,bhlv,bhlk->bhvk", w_c, v, k)
    n_out = jnp.exp(g_total + m_in - m_out)[..., None] * n_in \
        + jnp.einsum("bhl,bhlk->bhk", w_c, k)
    return h_out, {"C": c_out, "n": n_out, "m": m_out}


def mlstm_apply(
    p: Params, x: jax.Array, cfg: ModelConfig, state=None
) -> tuple[jax.Array, Params]:
    bsz, s, d = x.shape
    if state is None:
        state = init_mlstm_state(cfg, bsz)
    q, k, v, li, lf = _mlstm_qkv_gates(p, x, cfg)

    lc = MLSTM_CHUNK
    while s % lc:
        lc //= 2
    nch = s // lc

    def to_chunks(t):  # (B,H,S,...) -> (nch, B,H,lc,...)
        t = jnp.moveaxis(t, 2, 0).reshape((nch, lc) + t.shape[:2] + t.shape[3:])
        return jnp.moveaxis(t, 1, 3)  # (nch, B, H, lc, ...)

    def chunk(st, inputs):
        cq, ck, cv, cli, clf = inputs
        h, st = _mlstm_chunk(st, cq, ck, cv, cli, clf)
        return st, h

    st, hs = jax.lax.scan(
        jax.checkpoint(chunk), state,
        (to_chunks(q), to_chunks(k), to_chunks(v), to_chunks(li),
         to_chunks(lf)),
    )  # hs (nch, B, H, lc, hd)
    h = hs.transpose(0, 3, 1, 2, 4).reshape(s, bsz, cfg.n_heads, cfg.hd)
    h = jnp.moveaxis(h, 0, 1).reshape(bsz, s, d)
    return _mlstm_out(p, x, h, cfg), st


def _mlstm_out(p, x, h, cfg):
    dt = cfg.act_dtype
    h = _headwise_rms(h, cfg).astype(dt)
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["wo_gate"].astype(dt)))
    return jnp.einsum("bsd,de->bse", h * o, p["out"].astype(dt))


def _headwise_rms(h, cfg, eps=1e-6):
    b, s, d = h.shape
    hh = h.reshape(b, s, cfg.n_heads, cfg.hd).astype(jnp.float32)
    hh = hh * jax.lax.rsqrt(jnp.mean(hh * hh, axis=-1, keepdims=True) + eps)
    return hh.reshape(b, s, d)


def mlstm_decode(
    p: Params, x: jax.Array, state: Params, cfg: ModelConfig
) -> tuple[jax.Array, Params]:
    q, k, v, li, lf = _mlstm_qkv_gates(p, x, cfg)  # S=1
    h_t, st = mlstm_step(
        q[:, :, 0], k[:, :, 0], v[:, :, 0], li[:, :, 0], lf[:, :, 0], state
    )
    h = h_t.reshape(x.shape[0], 1, -1)
    return _mlstm_out(p, x, h, cfg), st


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig) -> Params:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "w_in": init_dense(ks[0], d, (4, h, hd), dt),
        "r": (jax.random.normal(ks[1], (h, hd, 4, hd), jnp.float32)
              / math.sqrt(hd)).astype(dt),
        "b": jnp.zeros((4, h, hd), dt)
        .at[1].set(3.0),  # forget-gate bias
        "out": init_dense(ks[2], d, (d,), dt),
    }


def init_slstm_state(cfg: ModelConfig, batch: int):
    h, hd = cfg.n_heads, cfg.hd
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, h, hd), NEG,
                                                  jnp.float32)}


def slstm_step(pre_x_t, r, state):
    """pre_x_t (B, 4, H, hd) = W x_t + b; r (H, hd, 4, hd) recurrent."""
    c, n, h_prev, m = state["c"], state["n"], state["h"], state["m"]
    pre = pre_x_t + jnp.einsum(
        "bhk,hkgj->bghj", h_prev, r.astype(jnp.float32)
    )
    li, fraw, zraw, oraw = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    lf = jax.nn.log_sigmoid(fraw)
    m_new = jnp.maximum(lf + m, li)
    i_p = jnp.exp(li - m_new)
    f_p = jnp.exp(lf + m - m_new)
    c = f_p * c + i_p * jnp.tanh(zraw)
    n = f_p * n + i_p
    h_t = jax.nn.sigmoid(oraw) * c / jnp.maximum(n, 1e-6)
    return h_t, {"c": c, "n": n, "h": h_t, "m": m_new}


def slstm_apply(
    p: Params, x: jax.Array, cfg: ModelConfig, state=None
) -> tuple[jax.Array, Params]:
    bsz, s, d = x.shape
    if state is None:
        state = init_slstm_state(cfg, bsz)
    dt = cfg.act_dtype
    pre = (jnp.einsum("bsd,dghk->bsghk", x, p["w_in"].astype(dt))
           + p["b"].astype(dt)).astype(jnp.float32)

    def step(st, pre_t):
        h_t, st = slstm_step(pre_t, p["r"], st)
        return st, h_t

    st, hs = jax.lax.scan(step, state, jnp.moveaxis(pre, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(bsz, s, d)
    h = _headwise_rms(h, cfg).astype(dt)
    return jnp.einsum("bsd,de->bse", h, p["out"].astype(dt)), st


def slstm_decode(
    p: Params, x: jax.Array, state: Params, cfg: ModelConfig
) -> tuple[jax.Array, Params]:
    dt = cfg.act_dtype
    pre = (jnp.einsum("bsd,dghk->bsghk", x, p["w_in"].astype(dt))
           + p["b"].astype(dt)).astype(jnp.float32)
    h_t, st = slstm_step(pre[:, 0], p["r"], state)
    h = _headwise_rms(h_t.reshape(x.shape[0], 1, -1), cfg).astype(dt)
    return jnp.einsum("bsd,de->bse", h, p["out"].astype(dt)), st
