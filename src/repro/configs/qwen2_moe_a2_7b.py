"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared experts (fused
shared hidden 4*1408=5632), qwen1.5 arch with QKV bias.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""

from repro.configs.base import ModelConfig, MoEConfig, register


@register("qwen2-moe-a2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab=151936,
        qkv_bias=True,
        moe=MoEConfig(
            n_experts=60, top_k=4, d_expert=1408, n_shared=4, d_shared=5632
        ),
    )
