"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 on every other layer.  [arXiv:2403.19887; hf]"""

from repro.configs.base import ModelConfig, MoEConfig, register


@register("jamba-1.5-large-398b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab=65536,
        # period-8 Jamba block: attention at slot 4, Mamba elsewhere (1:7);
        # MoE replaces the FFN on every other layer
        pattern=("mamba",) * 4 + ("attn",) + ("mamba",) * 3,
        moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576),
        moe_mask=(False, True) * 4,
        ssm_state=16,
        ssm_expand=2,
        # 398B on a 256-chip v5e pod: f32 master + f32 Adam moments would be
        # 18+ GB/chip; bf16 master/moments (8-bit-Adam-style trade) fits.
        param_dtype="bfloat16",
        long_context=True,
    )
