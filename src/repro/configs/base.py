"""Architecture config system: one frozen dataclass per assigned arch.

Every config is selectable by ``--arch <id>`` in the launchers.  ``reduced()``
derives the CPU smoke-test variant (same family/block pattern, tiny dims).

Block patterns: a layer stack is ``n_layers / len(pattern)`` repetitions of
``pattern`` (the scan unit), e.g. Jamba's 1:7 attention:Mamba interleave is a
period-8 pattern.  Kinds: ``attn`` | ``attn_chunked`` | ``mamba`` | ``mlstm``
| ``slstm``.  ``moe_mask`` marks which pattern slots use the MoE FFN.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden dim
    n_shared: int = 0  # shared ("always-on") experts
    d_shared: int = 0  # hidden dim of the fused shared expert (0 = none)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: tuple[str, ...] = ("attn",)
    moe: MoEConfig | None = None
    moe_mask: tuple[bool, ...] = ()  # per pattern slot; () = all-dense
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    chunk_size: int = 8192  # window for attn_chunked
    rope_on_global: bool = True  # iRoPE: global-attn layers skip RoPE
    # pad attention heads up to this count (0 = none) so the head axis
    # divides the 16-way TP mesh; pad heads are hard-masked to zero output,
    # keeping the math identical to the unpadded architecture (the standard
    # head-padding trade: a little extra FLOPs for clean sharding)
    attn_pad_heads: int = 0
    # SSM (mamba) geometry
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    # modality frontend stub: None | "vision" | "audio"
    frontend: str | None = None
    frontend_len: int = 1024  # patches/frames contributed by the stub
    # sub-quadratic long-context support (SSM/hybrid/chunked-attention):
    # gates the long_500k dry-run cell (pure full-attention archs skip it)
    long_context: bool = False
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # ---- derived ----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_super(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} not a multiple of "
            f"pattern period {len(self.pattern)}"
        )
        return self.n_layers // len(self.pattern)

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)


    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    def moe_for(self, slot: int) -> MoEConfig | None:
        if self.moe is None:
            return None
        if not self.moe_mask:
            return self.moe
        return self.moe if self.moe_mask[slot % len(self.pattern)] else None

    def n_params(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.hd
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for slot, kind in enumerate(self.pattern):
            n_rep = self.n_super
            if kind in ("attn", "attn_chunked"):
                qkv = d * hd * (self.n_heads + 2 * self.n_kv_heads)
                out = self.n_heads * hd * d
                blk = qkv + out
            elif kind == "mamba":
                di, st, r = self.d_inner, self.ssm_state, self.dt_rank
                blk = (
                    d * 2 * di + self.ssm_conv * di + di * (r + 2 * st)
                    + r * di + di * st + di + di * d
                )
            elif kind in ("mlstm", "slstm"):
                di = self.d_model
                blk = 4 * d * di + 3 * di + di * d  # qkv+gates+out (approx)
            else:
                raise ValueError(kind)
            moe = self.moe_for(slot)
            if moe is None:
                ffn = 3 * d * self.d_ff
            else:
                ffn = moe.n_experts * 3 * d * moe.d_expert + d * moe.n_experts
                if moe.d_shared:
                    ffn += 3 * d * moe.d_shared
            total += n_rep * (blk + ffn + 2 * d)
        return total

    def n_active_params(self) -> int:
        """Active (per-token) parameters — the MoE-aware N of 6·N·D."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        total = self.n_params()
        for slot in range(len(self.pattern)):
            moe = self.moe_for(slot)
            if moe is None:
                continue
            dense_all = moe.n_experts * 3 * d * moe.d_expert
            dense_active = moe.top_k * 3 * d * moe.d_expert
            total -= self.n_super * (dense_all - dense_active)
        return total

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        period = len(self.pattern)
        moe = None
        moe_mask = self.moe_mask
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=min(4, self.moe.n_experts),
                top_k=min(2, self.moe.top_k), d_expert=64,
                d_shared=64 if self.moe.d_shared else 0,
                n_shared=min(1, self.moe.n_shared),
            )
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=period * (2 if period <= 4 else 1),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2)
            if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            moe=moe,
            chunk_size=32,
            attn_pad_heads=0,
            ssm_state=8,
            frontend_len=8 if self.frontend else 1024,
        )


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    from repro import configs as _pkg  # ensure arch modules imported

    _pkg.load_all()
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    from repro import configs as _pkg

    _pkg.load_all()
    return sorted(_REGISTRY)
