"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (3:1 interleave), no separate FFN
(d_ff=0; the xLSTM blocks carry their own up/down projections).
[arXiv:2405.04517; unverified]"""

from repro.configs.base import ModelConfig, register


@register("xlstm-350m")
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        head_dim=256,
        d_ff=0,
        vocab=50304,
        pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        long_context=True,
    )
