"""Architecture registry — one module per assigned architecture."""

import importlib

_ARCH_MODULES = [
    "jamba_1_5_large_398b",
    "internvl2_76b",
    "mistral_large_123b",
    "yi_9b",
    "qwen2_72b",
    "codeqwen1_5_7b",
    "musicgen_medium",
    "xlstm_350m",
    "llama4_scout_17b_a16e",
    "qwen2_moe_a2_7b",
]

_loaded = False


def load_all() -> None:
    global _loaded
    if _loaded:
        return
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    _loaded = True


from repro.configs.base import ModelConfig, MoEConfig, get_config, list_archs  # noqa: E402,F401
