"""internvl2-76b [vlm] — InternViT frontend (stub) + 76B LLM backbone.
[arXiv:2404.16821; unverified]"""

from repro.configs.base import ModelConfig, register


@register("internvl2-76b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab=128256,
        frontend="vision",  # input_specs() provides patch embeddings
        frontend_len=1024,
    )
