"""musicgen-medium [audio] — decoder-only over EnCodec tokens; the EnCodec
frontend is a stub providing frame embeddings.  vocab=2048 is the best-case
regime for the KY token sampler (paper targets <=32-bin distributions; 2048
needs a 2-level 128-ary hierarchy).  [arXiv:2306.05284; hf]"""

from repro.configs.base import ModelConfig, register


@register("musicgen-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab=2048,
        frontend="audio",
        frontend_len=512,
        attn_pad_heads=32,
    )
