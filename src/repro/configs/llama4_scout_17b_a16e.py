"""llama4-scout-17b-a16e [moe] — 16 routed experts top-1 + 1 shared expert on
every layer; iRoPE-style chunked-local attention on 3 of 4 layers (the 4th is
global) => sub-quadratic, long_500k runs.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from repro.configs.base import ModelConfig, MoEConfig, register


@register("llama4-scout-17b-a16e")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=202048,
        pattern=("attn_chunked", "attn_chunked", "attn_chunked", "attn"),
        chunk_size=8192,
        rope_on_global=False,  # iRoPE: NoPE on the global-attention layers
        moe=MoEConfig(
            n_experts=16, top_k=1, d_expert=8192, n_shared=1, d_shared=8192
        ),
        long_context=True,
        attn_pad_heads=48,
    )
